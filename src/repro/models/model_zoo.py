"""Task-level model API: loss / train_step / prefill / serve_step per arch.

Everything here is functional and mesh-agnostic; sharding enters only through
(a) in/out shardings chosen by the launcher and (b) logical-axis constraints
inside the model code.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.launch.sharding import constrain
from repro.models import layers as L
from repro.models import mamba2 as ssm_lib
from repro.models import transformer as T
from repro.models.schema import (abstract_params, count_params, init_params,
                                 param_logical_axes)
from repro.optim import adamw


def _scan(body, init, xs, unroll=False):
    """lax.scan, or a fully unrolled Python loop for analysis builds."""
    if not unroll:
        return jax.lax.scan(body, init, xs)
    length = jax.tree.leaves(xs)[0].shape[0]
    carry, ys = init, []
    for i in range(length):
        carry, y = body(carry, jax.tree.map(lambda x: x[i], xs))
        ys.append(y)
    if ys and jax.tree.leaves(ys[0]):
        ys = jax.tree.map(lambda *a: jnp.stack(a), *ys)
    else:
        ys = ()
    return carry, ys


# ============================================================== batches
def batch_spec(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """Abstract input batch (ShapeDtypeStructs) for a (arch x shape) cell."""
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    if shape.kind == "train":
        if cfg.family == "enc_dec":
            return {
                "frames": jax.ShapeDtypeStruct((B, S, cfg.d_model),
                                               jnp.bfloat16),
                "tokens": jax.ShapeDtypeStruct((B, S), i32),
                "labels": jax.ShapeDtypeStruct((B, S), i32),
            }
        if cfg.family == "vlm":
            st = S - cfg.frontend_seq
            return {
                "tokens": jax.ShapeDtypeStruct((B, st), i32),
                "labels": jax.ShapeDtypeStruct((B, st), i32),
                "patch_embeds": jax.ShapeDtypeStruct(
                    (B, cfg.frontend_seq, cfg.d_model), jnp.bfloat16),
            }
        return {
            "tokens": jax.ShapeDtypeStruct((B, S), i32),
            "labels": jax.ShapeDtypeStruct((B, S), i32),
        }
    if shape.kind == "prefill":
        if cfg.family == "enc_dec":
            return {
                "frames": jax.ShapeDtypeStruct((B, S, cfg.d_model),
                                               jnp.bfloat16),
                "tokens": jax.ShapeDtypeStruct((B, S), i32),
            }
        if cfg.family == "vlm":
            return {
                "tokens": jax.ShapeDtypeStruct((B, S - cfg.frontend_seq), i32),
                "patch_embeds": jax.ShapeDtypeStruct(
                    (B, cfg.frontend_seq, cfg.d_model), jnp.bfloat16),
            }
        return {"tokens": jax.ShapeDtypeStruct((B, S), i32)}
    if shape.kind == "decode":
        return {"tokens": jax.ShapeDtypeStruct((B, 1), i32),
                "active": jax.ShapeDtypeStruct((B,), i32)}
    raise ValueError(shape.kind)


def make_batch(cfg: ModelConfig, shape: ShapeConfig, rng) -> Dict[str, Any]:
    """Concrete random batch matching ``batch_spec`` (smoke tests)."""
    spec = batch_spec(cfg, shape)
    out = {}
    for k, v in spec.items():
        rng, sub = jax.random.split(rng)
        if k == "active":
            out[k] = jnp.ones(v.shape, jnp.int32)
        elif v.dtype == jnp.int32:
            out[k] = jax.random.randint(sub, v.shape, 0, cfg.vocab_size,
                                        jnp.int32)
        else:
            out[k] = jax.random.normal(sub, v.shape, jnp.float32).astype(
                v.dtype)
    return out


# ============================================================== loss
def lm_loss(params, batch, cfg: ModelConfig, *, unroll=False):
    """Causal-LM cross-entropy (mean over tokens) + MoE aux loss."""
    if cfg.family == "enc_dec":
        h = T.enc_dec_forward(params, batch["frames"], batch["tokens"], cfg,
                              unroll=unroll)
        aux = jnp.zeros((), jnp.float32)
        labels = batch["labels"]
    elif cfg.family == "vlm":
        h, aux = T.decoder_forward(params, batch["tokens"], cfg,
                                   patch_embeds=batch["patch_embeds"],
                                   unroll=unroll)
        h = h[:, cfg.frontend_seq:]           # loss only on text positions
        labels = batch["labels"]
    else:
        h, aux = T.decoder_forward(params, batch["tokens"], cfg,
                                   unroll=unroll)
        labels = batch["labels"]
    logits = T.lm_logits(params, h, cfg)      # (B, S, V) fp32
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    loss = nll.mean() + aux
    return loss, {"nll": nll.mean(), "aux": aux}


# ============================================================== train state
class TrainState(NamedTuple):
    step: jax.Array
    params: Any
    opt: adamw.AdamWState


def init_state(cfg: ModelConfig, rng) -> TrainState:
    sch = T.model_schema(cfg)
    params = init_params(sch, rng, cfg.param_dtype)
    return TrainState(jnp.zeros((), jnp.int32), params, adamw.init(params))


def abstract_state(cfg: ModelConfig) -> TrainState:
    sch = T.model_schema(cfg)
    params = abstract_params(sch, cfg.param_dtype)
    return TrainState(
        jax.ShapeDtypeStruct((), jnp.int32), params,
        adamw.abstract_init(params))


def num_params(cfg: ModelConfig) -> int:
    return count_params(T.model_schema(cfg))


def active_params(cfg: ModelConfig) -> int:
    """Params touched per token (MoE: routed top_k of num_experts)."""
    total = num_params(cfg)
    if cfg.family != "moe":
        return total
    per_expert = 3 * cfg.d_model * cfg.d_ff
    routed = cfg.num_layers * cfg.num_experts * per_expert
    active = cfg.num_layers * cfg.top_k * per_expert
    return total - routed + active


# ============================================================== train step
def make_train_step(cfg: ModelConfig, hp: Optional[adamw.HParams] = None,
                    unroll: bool = False):
    hp = hp or adamw.HParams()

    def train_step(state: TrainState, batch):
        n_micro = max(cfg.num_microbatches, 1)

        def reshape_micro(x):
            b = x.shape[0]
            assert b % n_micro == 0, (b, n_micro)
            return x.reshape((n_micro, b // n_micro) + x.shape[1:])

        micro = jax.tree.map(reshape_micro, batch)
        loss_grad = jax.value_and_grad(
            lambda p, mb: lm_loss(p, mb, cfg, unroll=unroll), has_aux=True)

        def accum(carry, mb):
            gacc, lacc = carry
            (loss, metrics), grads = loss_grad(state.params, mb)
            if cfg.grad_schedule == "overlapped":
                # C1 analogue: per-microbatch reduce-scatter over the data
                # axis -> XLA overlaps collective i with compute of i+1.
                grads = _scatter_grads(grads, cfg)
            gacc = jax.tree.map(jnp.add, gacc, grads)
            return (gacc, lacc + loss), metrics

        zeros = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), state.params)
        if cfg.grad_schedule == "overlapped":
            zeros = _scatter_grads(zeros, cfg)
        if unroll:
            # analysis builds: straight-line HLO so cost_analysis counts
            # every microbatch (XLA counts a while body once)
            carry = (zeros, jnp.zeros((), jnp.float32))
            ms = []
            for i in range(n_micro):
                carry, mtr = accum(carry,
                                   jax.tree.map(lambda x: x[i], micro))
                ms.append(mtr)
            gsum, lsum = carry
            metrics = jax.tree.map(lambda *x: jnp.stack(x), *ms)
        else:
            (gsum, lsum), metrics = jax.lax.scan(
                accum, (zeros, jnp.zeros((), jnp.float32)), micro)
        grads = jax.tree.map(lambda g: g / n_micro, gsum)
        if cfg.grad_reduce_dtype == "bfloat16":
            # gradient compression: local accumulation stays f32; the
            # cross-data-axis reduction happens on bf16 (half the wire)
            grads = jax.tree.map(lambda g: g.astype(jnp.bfloat16), grads)
        loss = lsum / n_micro

        params, opt = adamw.update(state.params, grads, state.opt,
                                   state.step, hp)
        new_state = TrainState(state.step + 1, params, opt)
        out_metrics = {"loss": loss,
                       "nll": metrics["nll"].mean(),
                       "aux": metrics["aux"].mean(),
                       "grad_norm": adamw.global_norm(grads)}
        return new_state, out_metrics

    return train_step


def _scatter_grads(grads, cfg: ModelConfig):
    """Constrain grad leaves to the ZeRO-1 (data-scattered) shardings so
    GSPMD lowers the per-microbatch reduction as an (overlappable)
    reduce-scatter instead of one fused terminal all-reduce — and the
    scattered accumulation matches the optimizer-state sharding exactly
    (no extra reshard at the update)."""
    from repro.launch.sharding import active_rules, zero1_shardings
    rules = active_rules()
    if rules is None or "data" not in rules.axes:
        return grads
    sch = T.model_schema(cfg)
    zsh = zero1_shardings(rules, sch)
    return jax.tree.map(jax.lax.with_sharding_constraint, grads, zsh)


# ============================================================== serving
class DecodeState(NamedTuple):
    """Per-family decode state; unused fields are empty dicts/arrays."""
    cache: Any            # family-specific pytree
    cache_len: jax.Array  # (B,) filled positions


def abstract_decode_state(cfg: ModelConfig, shape: ShapeConfig):
    B, S = shape.global_batch, shape.seq_len
    bf16 = jnp.bfloat16
    KV, D = cfg.num_kv_heads, cfg.head_dim
    d_inner, nheads, conv_dim, _ = ssm_lib.mamba2_dims(cfg)
    N, P_ = cfg.ssm_state, cfg.ssm_head_dim

    def sds(shp, dt=bf16):
        return jax.ShapeDtypeStruct(shp, dt)

    if cfg.family in ("dense", "vlm", "moe"):
        cache = {"k": sds((cfg.num_layers, B, S, KV, D)),
                 "v": sds((cfg.num_layers, B, S, KV, D))}
    elif cfg.family == "enc_dec":
        cache = {"k": sds((cfg.dec_layers, B, S, KV, D)),
                 "v": sds((cfg.dec_layers, B, S, KV, D)),
                 "xk": sds((cfg.dec_layers, B, S, KV, D)),
                 "xv": sds((cfg.dec_layers, B, S, KV, D))}
    elif cfg.family == "ssm":
        cache = {"ssm": sds((cfg.num_layers, B, nheads, P_, N), jnp.float32),
                 "conv": sds((cfg.num_layers, B, cfg.conv_width - 1,
                              conv_dim))}
    elif cfg.family == "hybrid":
        periods = cfg.num_layers // cfg.attn_every
        cache = {"ssm": sds((periods, cfg.attn_every, B, nheads, P_, N),
                            jnp.float32),
                 "conv": sds((periods, cfg.attn_every, B,
                              cfg.conv_width - 1, conv_dim)),
                 "k": sds((periods, B, S, KV, D)),
                 "v": sds((periods, B, S, KV, D))}
    else:
        raise ValueError(cfg.family)
    return DecodeState(cache, jax.ShapeDtypeStruct((B,), jnp.int32))


def init_decode_state(cfg: ModelConfig, shape: ShapeConfig,
                      fill_len: Optional[int] = None) -> DecodeState:
    ab = abstract_decode_state(cfg, shape)
    cache = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), ab.cache)
    fl = shape.seq_len - 1 if fill_len is None else fill_len
    return DecodeState(cache, jnp.full((shape.global_batch,), fl, jnp.int32))


def decode_state_logical_axes(cfg: ModelConfig):
    """Logical axes for the decode-state pytree (for shardings)."""
    kv4 = (None, "cache_batch", "cache_seq", "kv_heads", None)
    if cfg.family in ("dense", "vlm", "moe"):
        cache = {"k": kv4, "v": kv4}
    elif cfg.family == "enc_dec":
        cache = {"k": kv4, "v": kv4, "xk": kv4, "xv": kv4}
    elif cfg.family == "ssm":
        cache = {"ssm": (None, "cache_batch", "ssm_heads", None, None),
                 "conv": (None, "cache_batch", None, "conv_dim")}
    elif cfg.family == "hybrid":
        cache = {"ssm": (None, None, "cache_batch", "ssm_heads", None, None),
                 "conv": (None, None, "cache_batch", None, "conv_dim"),
                 "k": (None, "cache_batch", "cache_seq", "kv_heads", None),
                 "v": (None, "cache_batch", "cache_seq", "kv_heads", None)}
    else:
        raise ValueError(cfg.family)
    return DecodeState(cache, ("cache_batch",))


# -------------------------------------------------------------- prefill
def make_prefill(cfg: ModelConfig, shape: ShapeConfig, unroll: bool = False):
    """Returns fn(params, batch) -> (last_logits, DecodeState)."""

    def prefill(params, batch):
        B = shape.global_batch
        if cfg.family in ("dense", "vlm", "moe"):
            h, caches = _decoder_prefill(params, batch, cfg, unroll)
            cache = caches
        elif cfg.family == "enc_dec":
            h, cache = _encdec_prefill(params, batch, cfg, unroll)
        elif cfg.family in ("ssm", "hybrid"):
            h, cache = _ssm_prefill(params, batch, cfg, unroll)
        else:
            raise ValueError(cfg.family)
        logits = T.lm_logits(params, h[:, -1:], cfg)
        cache_len = jnp.full((B,), _prefill_len(cfg, shape), jnp.int32)
        return logits, DecodeState(cache, cache_len)

    return prefill


def _prefill_len(cfg, shape):
    return shape.seq_len


def _decoder_prefill(params, batch, cfg, unroll):
    tokens = batch["tokens"]
    h = T.embed_tokens(params, tokens, cfg)
    if cfg.family == "vlm":
        pe = constrain(batch["patch_embeds"].astype(h.dtype),
                       "batch", None, "embed")
        h = jnp.concatenate([pe, h], axis=1)
    S = h.shape[1]

    def body(carry, lp):
        x = carry
        x, kv = L.attention_block(lp["attn"], x, cfg, causal=True)
        if cfg.family == "moe":
            from repro.models import moe as moe_lib
            x, _ = moe_lib.moe_block(lp["moe"], x, cfg)
        else:
            x = L.swiglu_block(lp["mlp"], x, cfg)
        k, v = kv
        return x, (k.astype(jnp.bfloat16), v.astype(jnp.bfloat16))

    if unroll:
        ks, vs = [], []
        for i in range(cfg.num_layers):
            h, (k, v) = body(h, jax.tree.map(lambda x: x[i], params["layers"]))
            ks.append(k); vs.append(v)
        cache = {"k": jnp.stack(ks), "v": jnp.stack(vs)}
    else:
        h, (ks, vs) = jax.lax.scan(body, h, params["layers"])
        cache = {"k": ks, "v": vs}
    return h, cache


def _encdec_prefill(params, batch, cfg, unroll):
    enc_out = T.encoder_forward(params, batch["frames"], cfg, unroll=unroll)
    dt = jnp.dtype(cfg.compute_dtype)
    h = T.embed_tokens(params, batch["tokens"], cfg)

    def body(carry, lp):
        x = carry
        x, kv = L.attention_block(lp["self_attn"], x, cfg, causal=True)
        ca = lp["cross_attn"]
        hn = L.rms_norm(x, ca["norm"], cfg.norm_eps).astype(dt)
        q = jnp.einsum("bsd,dhk->bshk", hn, ca["wq"].astype(dt))
        xk = jnp.einsum("bsd,dhk->bshk", enc_out.astype(dt),
                        ca["wk"].astype(dt))
        xv = jnp.einsum("bsd,dhk->bshk", enc_out.astype(dt),
                        ca["wv"].astype(dt))
        att = L.full_attention(q, xk, xv, causal=False)
        x = x + jnp.einsum("bshk,hkd->bsd", att, ca["wo"].astype(dt))
        x = L.swiglu_block(lp["mlp"], x, cfg)
        k, v = kv
        return x, (k.astype(jnp.bfloat16), v.astype(jnp.bfloat16),
                   xk.astype(jnp.bfloat16), xv.astype(jnp.bfloat16))

    h, (ks, vs, xks, xvs) = _scan(body, h, params["dec_layers"],
                                  unroll=unroll)
    return h, {"k": ks, "v": vs, "xk": xks, "xv": xvs}


def _ssm_prefill(params, batch, cfg, unroll):
    h = T.embed_tokens(params, batch["tokens"], cfg)
    W = cfg.conv_width
    if cfg.family == "ssm":
        def body(carry, lp):
            x = carry
            x, (st, conv_tail) = ssm_lib.mamba2_block(lp, x, cfg)
            return x, (st, conv_tail)
        h, (ssm_states, convs) = _scan(body, h, params["layers"],
                                       unroll=unroll)
        return h, {"ssm": ssm_states.astype(jnp.float32),
                   "conv": convs.astype(jnp.bfloat16)}
    else:  # hybrid
        periods = cfg.num_layers // cfg.attn_every
        shared = params["shared"]

        def period_body(carry, pp):
            x = carry
            def inner(c, lp):
                c, (st, conv_tail) = ssm_lib.mamba2_block(lp, c, cfg)
                return c, (st, conv_tail)
            x, (sts, convs) = _scan(inner, x, pp, unroll=unroll)
            x, kv = L.attention_block(shared["attn"], x, cfg, causal=True)
            x = L.swiglu_block(shared["mlp"], x, cfg)
            k, v = kv
            return x, (sts, convs, k.astype(jnp.bfloat16),
                       v.astype(jnp.bfloat16))

        h, (sts, convs, ks, vs) = _scan(period_body, h, params["mamba"],
                                        unroll=unroll)
        return h, {"ssm": sts.astype(jnp.float32),
                   "conv": convs.astype(jnp.bfloat16),
                   "k": ks, "v": vs}


# ------------------------------------------------- chunked bulk prefill
# Families whose prefill needs only ``tokens`` (no frames / patch embeds)
# and can therefore be bulk-prefilled by a serving engine.
BULK_PREFILL_FAMILIES = ("dense", "moe", "ssm", "hybrid")
# Causal-attention families ignore a padded tail (position i never attends
# to j > i), so a prompt chunk may be right-padded to a bucket size.
# Recurrent families (ssm/hybrid) must never feed pad tokens through the
# state recurrence; their chunks are always fully real.
PAD_SAFE_FAMILIES = ("dense", "moe")


def make_bulk_prefill(cfg: ModelConfig, shape: ShapeConfig, chunk: int):
    """Chunked bulk prefill into one slot of a batched decode cache.

    Returns ``fn(params, state, tokens, slot, n_real) -> DecodeState``:
    runs ``make_prefill`` over a ``(1, chunk)`` token buffer and scatters
    the resulting cache columns into row ``slot`` of ``state`` (positions
    ``[0, chunk)`` on every ``cache_seq`` axis; whole-row replacement for
    recurrent-state leaves), then sets ``cache_len[slot] = n_real``.

    ``slot`` and ``n_real`` are traced, so one compiled function per
    (cfg, engine shape, chunk bucket) serves every slot and prompt length
    — the bucket list bounds the number of recompiles.

    Bit-exactness: the prefill forward computes the same per-position
    math as the streamed decode path (verified by the engine equivalence
    tests), so a bulk-prefilled slot continues identically to one that
    streamed its prompt one token per step.
    """
    pshape = ShapeConfig(f"prefill_chunk{chunk}", chunk, 1, "prefill")
    prefill = make_prefill(cfg, pshape)
    batch_axes = {k: ax.index("cache_batch")
                  for k, ax in decode_state_logical_axes(cfg).cache.items()}

    def bulk_prefill(params, state: DecodeState, tokens, slot, n_real):
        _, pstate = prefill(params, {"tokens": tokens})
        new_cache = {}
        for key, leaf in state.cache.items():
            upd = pstate.cache[key].astype(leaf.dtype)
            starts = [0] * leaf.ndim
            starts[batch_axes[key]] = slot
            new_cache[key] = jax.lax.dynamic_update_slice(
                leaf, upd, tuple(starts))
        cache_len = state.cache_len.at[slot].set(
            jnp.asarray(n_real, jnp.int32))
        return DecodeState(new_cache, cache_len)

    return bulk_prefill


# ------------------------------------------------- sync-free decode loop
class SampleState(NamedTuple):
    """Device-resident continuous-batching state for the decode hot loop.

    Everything the per-step control flow needs lives on device, so a
    multi-step decode window performs zero device->host transfers; the
    host reconciles progress from its own exact projection and fetches
    ``out_buf`` only at completion/drain boundaries.
    """
    next_tok: jax.Array   # (B, 1) int32 — token each slot feeds next step
    active: jax.Array     # (B,)  int32 — slot occupied and not finished
    fed: jax.Array        # (B,)  int32 — prompt+generated tokens fed so far
    plen: jax.Array       # (B,)  int32 — prompt length
    maxfed: jax.Array     # (B,)  int32 — fed value at which the slot is done
    out_buf: jax.Array    # (B, S) int32 — generated tokens at index fed-plen
    rng: jax.Array        # PRNG key for device-side temperature sampling


def init_sample_state(cfg: ModelConfig, shape: ShapeConfig,
                      seed: int = 0) -> SampleState:
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    return SampleState(
        next_tok=jnp.zeros((B, 1), i32),
        active=jnp.zeros((B,), i32),
        fed=jnp.zeros((B,), i32),
        plen=jnp.ones((B,), i32),
        maxfed=jnp.zeros((B,), i32),
        out_buf=jnp.zeros((B, S), i32),
        rng=jax.random.PRNGKey(seed),
    )


def make_decode_loop(cfg: ModelConfig, shape: ShapeConfig, n_steps: int,
                     temperature: float = 0.0, unroll: bool = False,
                     eos_token: Optional[int] = None, serve_step=None):
    """Fused sample-and-advance decode: ``n_steps`` serve_steps in ONE
    dispatch, sampling and continuous-batching bookkeeping on device.

    ``serve_step`` injects an alternative per-token step with the same
    calling convention (paged-cache engines pass
    ``make_paged_serve_step``'s); the sampling/bookkeeping body treats
    the cache state opaquely, so dense and paged loops share it —
    which is what makes their token streams bit-identical by
    construction.

    Returns ``fn(params, DecodeState, SampleState, prompt_buf) ->
    (DecodeState, SampleState)``.  Per inner step, each active slot feeds
    ``next_tok``; mid-prefill slots pull their next token from
    ``prompt_buf`` (B, S) while finished-prefill slots take the sampled
    token, write it into ``out_buf`` and self-deactivate once ``fed``
    reaches ``maxfed`` — no host round-trip anywhere in the loop.

    ``eos_token`` enables device-side early exit: a slot that samples the
    EOS token writes it into ``out_buf`` and clears its own active flag,
    so the remaining fused steps of the window skip it entirely.  Tokens
    emitted before (and including) EOS are bit-identical to the
    non-early-exit loop — the extra done condition only fires on the step
    that produced the EOS sample.
    """
    if serve_step is None:
        serve_step = make_serve_step(cfg, shape, unroll=unroll)
    B, S = shape.global_batch, shape.seq_len

    def decode_loop(params, state: DecodeState, sample: SampleState,
                    prompt_buf):
        bidx = jnp.arange(B)

        def body(carry, _):
            state, s = carry
            logits, state = serve_step(
                params, state, {"tokens": s.next_tok, "active": s.active})
            last = logits[:, -1, :]
            rng = s.rng
            if temperature > 0:
                rng, sub = jax.random.split(rng)
                sampled = jax.random.categorical(
                    sub, last.astype(jnp.float32) / temperature, axis=-1)
            else:
                sampled = jnp.argmax(last, axis=-1)
            sampled = sampled.astype(jnp.int32)
            act = s.active > 0
            fed2 = s.fed + s.active
            generating = act & (fed2 >= s.plen)
            oi = jnp.clip(fed2 - s.plen, 0, S - 1)
            out_buf = s.out_buf.at[bidx, oi].set(
                jnp.where(generating, sampled, s.out_buf[bidx, oi]))
            nxt = jnp.where(fed2 < s.plen,
                            prompt_buf[bidx, jnp.clip(fed2, 0, S - 1)],
                            sampled)
            next_tok = jnp.where(act[:, None], nxt[:, None], s.next_tok)
            done = generating & (fed2 >= s.maxfed)
            if eos_token is not None:
                done = done | (generating & (sampled == eos_token))
            active = s.active * (1 - done.astype(jnp.int32))
            return (state, SampleState(next_tok, active, fed2, s.plen,
                                       s.maxfed, out_buf, rng)), ()

        (state, sample), _ = jax.lax.scan(body, (state, sample), None,
                                          length=n_steps)
        return state, sample

    return decode_loop


# -------------------------------------------------------------- decode
def make_serve_step(cfg: ModelConfig, shape: ShapeConfig,
                    unroll: bool = False):
    """Returns fn(params, DecodeState, batch) -> (logits, DecodeState).

    One new token per sequence against a cache of ``shape.seq_len``.
    """

    def serve_step(params, state: DecodeState, batch):
        tokens = batch["tokens"]            # (B, 1)
        active = batch.get("active")
        if active is None:
            active = jnp.ones((tokens.shape[0],), jnp.int32)
        act = active.astype(jnp.bool_)
        h = T.embed_tokens(params, tokens, cfg)
        cache, clen = state.cache, state.cache_len

        if cfg.family in ("dense", "vlm", "moe"):
            def body(carry, xs):
                x = carry
                lp, ck, cv = xs
                x, (ck, cv) = L.decode_attention(
                    lp["attn"], x, cfg, cache_k=ck, cache_v=cv,
                    cache_len=clen, active=act)
                if cfg.family == "moe":
                    from repro.models import moe as moe_lib
                    x, _ = moe_lib.moe_block(lp["moe"], x, cfg)
                else:
                    x = L.swiglu_block(lp["mlp"], x, cfg)
                return x, (ck, cv)
            h, (ks, vs) = _scan(
                body, h, (params["layers"], cache["k"], cache["v"]),
                unroll=unroll)
            new_cache = {"k": ks, "v": vs}
        elif cfg.family == "enc_dec":
            dt = jnp.dtype(cfg.compute_dtype)
            def body(carry, xs):
                x = carry
                lp, ck, cv, xk, xv = xs
                x, (ck, cv) = L.decode_attention(
                    lp["self_attn"], x, cfg, cache_k=ck, cache_v=cv,
                    cache_len=clen, active=act)
                ca = lp["cross_attn"]
                hn = L.rms_norm(x, ca["norm"], cfg.norm_eps).astype(dt)
                q = jnp.einsum("bsd,dhk->bshk", hn, ca["wq"].astype(dt))
                att = L.full_attention(q, xk.astype(dt), xv.astype(dt),
                                       causal=False)
                x = x + jnp.einsum("bshk,hkd->bsd", att,
                                   ca["wo"].astype(dt))
                x = L.swiglu_block(lp["mlp"], x, cfg)
                return x, (ck, cv)
            h, (ks, vs) = _scan(
                body, h, (params["dec_layers"], cache["k"], cache["v"],
                          cache["xk"], cache["xv"]), unroll=unroll)
            new_cache = dict(cache, k=ks, v=vs)
        elif cfg.family == "ssm":
            def body(carry, xs):
                x = carry
                lp, st, cs = xs
                x, (st, cs) = ssm_lib.mamba2_block(
                    lp, x, cfg, ssm_state=st, conv_state=cs, active=act)
                return x, (st, cs)
            h, (ssm, conv) = _scan(
                body, h, (params["layers"], cache["ssm"], cache["conv"]),
                unroll=unroll)
            new_cache = {"ssm": ssm, "conv": conv}
        elif cfg.family == "hybrid":
            shared = params["shared"]
            def period_body(carry, xs):
                x = carry
                pp, st, cs, ck, cv = xs
                def inner(c, ys):
                    lp, s1, c1 = ys
                    c, (s1, c1) = ssm_lib.mamba2_block(
                        lp, c, cfg, ssm_state=s1, conv_state=c1, active=act)
                    return c, (s1, c1)
                x, (st, cs) = _scan(inner, x, (pp, st, cs), unroll=unroll)
                x, (ck, cv) = L.decode_attention(
                    shared["attn"], x, cfg, cache_k=ck, cache_v=cv,
                    cache_len=clen, active=act)
                x = L.swiglu_block(shared["mlp"], x, cfg)
                return x, (st, cs, ck, cv)
            h, (ssm, conv, ks, vs) = _scan(
                period_body, h,
                (params["mamba"], cache["ssm"], cache["conv"],
                 cache["k"], cache["v"]), unroll=unroll)
            new_cache = {"ssm": ssm, "conv": conv, "k": ks, "v": vs}
        else:
            raise ValueError(cfg.family)

        logits = T.lm_logits(params, h, cfg)
        return logits, DecodeState(new_cache, clen + active)

    return serve_step


# ============================================================ paged cache
class PagedDecodeState(NamedTuple):
    """Decode state over a *paged* KV cache (vLLM-style block pool).

    KV leaves are one shared pool ``(..., num_blocks, block_size, KV, D)``
    instead of dense per-lane columns; each lane addresses its logical
    positions through ``block_tables`` (B, max_blocks) of physical pool
    rows.  Unallocated table entries hold the sentinel ``num_blocks``
    (out of range): gathers clamp it (garbage always masked by kv_len /
    causality), scatters drop it (``mode="drop"``) — so stale tables can
    never corrupt live blocks.  Recurrent leaves (ssm/conv) are O(1) per
    lane and stay lane-indexed.
    """
    cache: Any
    cache_len: jax.Array     # (B,) filled positions
    block_tables: jax.Array  # (B, max_blocks) int32 physical pool rows


def paged_kv_keys(cfg: ModelConfig) -> Tuple[str, ...]:
    """Cache keys stored in the block pool (vs. per-lane recurrent)."""
    if cfg.family in ("dense", "moe"):
        return ("k", "v")
    if cfg.family == "ssm":
        return ()
    if cfg.family == "hybrid":
        return ("k", "v")
    raise ValueError(cfg.family)


def abstract_paged_decode_state(cfg: ModelConfig, shape: ShapeConfig,
                                block_size: int, num_blocks: int):
    """Paged analogue of ``abstract_decode_state``.

    ``shape.global_batch`` is the number of decode *lanes* (concurrent
    slots); pool memory is ``num_blocks`` x ``block_size`` kv columns,
    decoupled from lanes x seq_len — the whole point of paging.  Only
    ``BULK_PREFILL_FAMILIES`` minus enc_dec/vlm are supported (the
    serving engine's admission path).
    """
    B, S = shape.global_batch, shape.seq_len
    assert S % block_size == 0, (S, block_size)
    mb = S // block_size
    bf16 = jnp.bfloat16
    KV, D = cfg.num_kv_heads, cfg.head_dim
    d_inner, nheads, conv_dim, _ = ssm_lib.mamba2_dims(cfg)
    N, P_ = cfg.ssm_state, cfg.ssm_head_dim

    def sds(shp, dt=bf16):
        return jax.ShapeDtypeStruct(shp, dt)

    if cfg.family in ("dense", "moe"):
        cache = {"k": sds((cfg.num_layers, num_blocks, block_size, KV, D)),
                 "v": sds((cfg.num_layers, num_blocks, block_size, KV, D))}
    elif cfg.family == "ssm":
        cache = {"ssm": sds((cfg.num_layers, B, nheads, P_, N), jnp.float32),
                 "conv": sds((cfg.num_layers, B, cfg.conv_width - 1,
                              conv_dim))}
    elif cfg.family == "hybrid":
        periods = cfg.num_layers // cfg.attn_every
        cache = {"ssm": sds((periods, cfg.attn_every, B, nheads, P_, N),
                            jnp.float32),
                 "conv": sds((periods, cfg.attn_every, B,
                              cfg.conv_width - 1, conv_dim)),
                 "k": sds((periods, num_blocks, block_size, KV, D)),
                 "v": sds((periods, num_blocks, block_size, KV, D))}
    else:
        raise ValueError(f"paged cache unsupported for {cfg.family}")
    return PagedDecodeState(cache,
                            jax.ShapeDtypeStruct((B,), jnp.int32),
                            jax.ShapeDtypeStruct((B, mb), jnp.int32))


def init_paged_decode_state(cfg: ModelConfig, shape: ShapeConfig,
                            block_size: int,
                            num_blocks: int) -> PagedDecodeState:
    ab = abstract_paged_decode_state(cfg, shape, block_size, num_blocks)
    cache = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), ab.cache)
    B, mb = ab.block_tables.shape
    return PagedDecodeState(
        cache, jnp.zeros((B,), jnp.int32),
        jnp.full((B, mb), num_blocks, jnp.int32))   # all-sentinel tables


def make_paged_serve_step(cfg: ModelConfig, shape: ShapeConfig,
                          block_size: int, num_blocks: int,
                          unroll: bool = False, impl: str = "auto"):
    """Paged ``make_serve_step``: fn(params, PagedDecodeState, batch) ->
    (logits, PagedDecodeState).  Same sampling-visible math as the dense
    step — the attention core is bit-identical on CPU backends and a
    Pallas paged-attention kernel on TPU.
    """

    def serve_step(params, state: PagedDecodeState, batch):
        tokens = batch["tokens"]            # (B, 1)
        active = batch.get("active")
        if active is None:
            active = jnp.ones((tokens.shape[0],), jnp.int32)
        act = active.astype(jnp.bool_)
        h = T.embed_tokens(params, tokens, cfg)
        cache, clen, bt = state.cache, state.cache_len, state.block_tables

        if cfg.family in ("dense", "moe"):
            def body(carry, xs):
                x = carry
                lp, pk, pv = xs
                x, (pk, pv) = L.paged_decode_attention(
                    lp["attn"], x, cfg, pool_k=pk, pool_v=pv,
                    block_tables=bt, cache_len=clen, active=act, impl=impl)
                if cfg.family == "moe":
                    from repro.models import moe as moe_lib
                    x, _ = moe_lib.moe_block(lp["moe"], x, cfg)
                else:
                    x = L.swiglu_block(lp["mlp"], x, cfg)
                return x, (pk, pv)
            h, (ks, vs) = _scan(
                body, h, (params["layers"], cache["k"], cache["v"]),
                unroll=unroll)
            new_cache = {"k": ks, "v": vs}
        elif cfg.family == "ssm":
            def body(carry, xs):
                x = carry
                lp, st, cs = xs
                x, (st, cs) = ssm_lib.mamba2_block(
                    lp, x, cfg, ssm_state=st, conv_state=cs, active=act)
                return x, (st, cs)
            h, (ssm, conv) = _scan(
                body, h, (params["layers"], cache["ssm"], cache["conv"]),
                unroll=unroll)
            new_cache = {"ssm": ssm, "conv": conv}
        elif cfg.family == "hybrid":
            shared = params["shared"]
            def period_body(carry, xs):
                x = carry
                pp, st, cs, pk, pv = xs
                def inner(c, ys):
                    lp, s1, c1 = ys
                    c, (s1, c1) = ssm_lib.mamba2_block(
                        lp, c, cfg, ssm_state=s1, conv_state=c1, active=act)
                    return c, (s1, c1)
                x, (st, cs) = _scan(inner, x, (pp, st, cs), unroll=unroll)
                x, (pk, pv) = L.paged_decode_attention(
                    shared["attn"], x, cfg, pool_k=pk, pool_v=pv,
                    block_tables=bt, cache_len=clen, active=act, impl=impl)
                x = L.swiglu_block(shared["mlp"], x, cfg)
                return x, (st, cs, pk, pv)
            h, (ssm, conv, ks, vs) = _scan(
                period_body, h,
                (params["mamba"], cache["ssm"], cache["conv"],
                 cache["k"], cache["v"]), unroll=unroll)
            new_cache = {"ssm": ssm, "conv": conv, "k": ks, "v": vs}
        else:
            raise ValueError(cfg.family)

        logits = T.lm_logits(params, h, cfg)
        return logits, PagedDecodeState(new_cache, clen + active, bt)

    return serve_step


def make_paged_decode_loop(cfg: ModelConfig, shape: ShapeConfig,
                           n_steps: int, block_size: int, num_blocks: int,
                           temperature: float = 0.0,
                           eos_token: Optional[int] = None,
                           impl: str = "auto"):
    """``make_decode_loop`` over a paged cache — shares the exact
    sampling/bookkeeping body, so token streams match dense decode
    bit-for-bit."""
    step = make_paged_serve_step(cfg, shape, block_size, num_blocks,
                                 impl=impl)
    return make_decode_loop(cfg, shape, n_steps, temperature=temperature,
                            eos_token=eos_token, serve_step=step)


def make_paged_bulk_prefill(cfg: ModelConfig, shape: ShapeConfig,
                            chunk: int, block_size: int, num_blocks: int,
                            first_chunk: bool = False):
    """State-continued chunk prefill into one slot of a paged cache.

    Returns ``fn(params, state, tokens, slot, off, n_real) ->
    PagedDecodeState``: prefills a ``(1, chunk)`` token buffer whose
    first token sits at absolute position ``off`` of slot ``slot``.
    Attention kv lands in the slot's blocks through its table (a
    block-table append); attention reads causally over history + chunk
    (prefill-with-history).  Recurrent (ssm/conv) leaves continue from
    the slot's carried state — zeros when ``off == 0`` — via the SSD
    ``init_state`` threading, which is exactly equivalent to one long
    prefill over the concatenated chunks.  Sets
    ``cache_len[slot] = off + n_real``.

    ``slot``/``off``/``n_real`` are traced: one compiled function per
    (cfg, shape, chunk bucket, block geometry) covers every slot,
    chunk index, and real length.  ``first_chunk=True`` specializes the
    compiled function for ``off == 0`` (fresh admission, the hot case
    under churn): the kv attention skips the history gather — every
    gathered position would be masked — and recurrent leaves start from
    literal zeros instead of a gather-and-select.
    """
    assert cfg.family in BULK_PREFILL_FAMILIES, cfg.family
    mb = shape.seq_len // block_size

    def paged_prefill(params, state: PagedDecodeState, tokens, slot, off,
                      n_real):
        def take_lane(leaf, ax):
            return jax.lax.dynamic_slice_in_dim(leaf, slot, 1, axis=ax)

        cache, bt = state.cache, state.block_tables
        bt_row = jax.lax.dynamic_slice(bt, (slot, 0), (1, mb))[0]
        first = off == 0
        h = T.embed_tokens(params, tokens, cfg)
        new_cache = dict(cache)

        if cfg.family in ("dense", "moe"):
            def body(carry, xs):
                x = carry
                lp, pk, pv = xs
                x, pk, pv = L.paged_chunk_attention(
                    lp["attn"], x, cfg, pool_k=pk, pool_v=pv,
                    bt_row=bt_row, off=off, history=not first_chunk)
                if cfg.family == "moe":
                    from repro.models import moe as moe_lib
                    x, _ = moe_lib.moe_block(lp["moe"], x, cfg)
                else:
                    x = L.swiglu_block(lp["mlp"], x, cfg)
                return x, (pk, pv)
            _, (ks, vs) = jax.lax.scan(
                body, h, (params["layers"], cache["k"], cache["v"]))
            new_cache = {"k": ks, "v": vs}
        elif cfg.family == "ssm":
            if first_chunk:
                ssm0 = jnp.zeros_like(take_lane(cache["ssm"], 1))
                conv0 = jnp.zeros_like(take_lane(cache["conv"], 1))
            else:
                ssm0 = jnp.where(first, 0.0, take_lane(cache["ssm"], 1))
                conv0 = jnp.where(first, 0, take_lane(cache["conv"], 1))
            def body(carry, xs):
                x = carry
                lp, s0, c0 = xs
                x, (st, cv) = ssm_lib.mamba2_block(
                    lp, x, cfg, init_ssm=s0, init_conv=c0)
                return x, (st, cv)
            _, (ssm, conv) = jax.lax.scan(
                body, h, (params["layers"], ssm0, conv0))
            new_cache = {
                "ssm": jax.lax.dynamic_update_slice(
                    cache["ssm"], ssm.astype(cache["ssm"].dtype),
                    (0, slot, 0, 0, 0)),
                "conv": jax.lax.dynamic_update_slice(
                    cache["conv"], conv.astype(cache["conv"].dtype),
                    (0, slot, 0, 0))}
        elif cfg.family == "hybrid":
            if first_chunk:
                ssm0 = jnp.zeros_like(take_lane(cache["ssm"], 2))
                conv0 = jnp.zeros_like(take_lane(cache["conv"], 2))
            else:
                ssm0 = jnp.where(first, 0.0, take_lane(cache["ssm"], 2))
                conv0 = jnp.where(first, 0, take_lane(cache["conv"], 2))
            shared = params["shared"]
            def period_body(carry, xs):
                x = carry
                pp, s0, c0, pk, pv = xs
                def inner(c, ys):
                    lp, s1, c1 = ys
                    c, (st, cv) = ssm_lib.mamba2_block(
                        lp, c, cfg, init_ssm=s1, init_conv=c1)
                    return c, (st, cv)
                x, (sts, cvs) = jax.lax.scan(inner, x, (pp, s0, c0))
                x, pk, pv = L.paged_chunk_attention(
                    shared["attn"], x, cfg, pool_k=pk, pool_v=pv,
                    bt_row=bt_row, off=off, history=not first_chunk)
                x = L.swiglu_block(shared["mlp"], x, cfg)
                return x, (sts, cvs, pk, pv)
            _, (ssm, conv, ks, vs) = jax.lax.scan(
                period_body, h,
                (params["mamba"], ssm0, conv0, cache["k"], cache["v"]))
            new_cache = {
                "ssm": jax.lax.dynamic_update_slice(
                    cache["ssm"], ssm.astype(cache["ssm"].dtype),
                    (0, 0, slot, 0, 0, 0)),
                "conv": jax.lax.dynamic_update_slice(
                    cache["conv"], conv.astype(cache["conv"].dtype),
                    (0, 0, slot, 0, 0)),
                "k": ks, "v": vs}
        else:
            raise ValueError(cfg.family)

        cache_len = state.cache_len.at[slot].set(
            jnp.asarray(off + n_real, jnp.int32))
        return PagedDecodeState(new_cache, cache_len, bt)

    return paged_prefill
