"""Decoder-only LM (dense / vlm / moe / hybrid hosts) and encoder-decoder.

Layers are stacked on a leading 'layers' dim and executed with
``jax.lax.scan`` (compile-time / HLO-size control at 26B+ scale).  For
roofline cost accounting an ``unroll`` flag replaces the scan with a Python
loop (see DESIGN.md §6: XLA cost_analysis counts a while body once).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.launch.sharding import constrain
from repro.models import layers as L
from repro.models import moe as moe_lib
from repro.models import mamba2 as ssm_lib
from repro.models.schema import Spec


# ============================================================== schemas
def attn_schema(cfg: ModelConfig, stacked: Optional[int], prefix="layers"):
    st = (stacked,) if stacked is not None else ()
    sa = (prefix,) if stacked is not None else ()
    H, KV, D, d = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim, cfg.d_model
    return {
        "norm": Spec(st + (d,), sa + (None,), "ones"),
        "wq": Spec(st + (d, H, D), sa + ("embed", "heads", "head_dim")),
        "wk": Spec(st + (d, KV, D), sa + ("embed", "kv_heads", "head_dim")),
        "wv": Spec(st + (d, KV, D), sa + ("embed", "kv_heads", "head_dim")),
        "wo": Spec(st + (H, D, d), sa + ("heads", "head_dim", "embed")),
    }


def mlp_schema(cfg: ModelConfig, stacked: Optional[int], prefix="layers"):
    st = (stacked,) if stacked is not None else ()
    sa = (prefix,) if stacked is not None else ()
    d, f = cfg.d_model, cfg.d_ff
    return {
        "norm": Spec(st + (d,), sa + (None,), "ones"),
        "w_gate": Spec(st + (d, f), sa + ("embed", "ff")),
        "w_up": Spec(st + (d, f), sa + ("embed", "ff")),
        "w_down": Spec(st + (f, d), sa + ("ff", "embed")),
    }


def decoder_lm_schema(cfg: ModelConfig):
    """dense / vlm / moe decoder-only LM."""
    Lc = cfg.num_layers
    sch = {
        "embed": Spec((cfg.padded_vocab, cfg.d_model), ("vocab", "embed_tp"),
                      "embed"),
        "final_norm": Spec((cfg.d_model,), (None,), "ones"),
        "layers": {"attn": attn_schema(cfg, Lc)},
    }
    if cfg.family == "moe":
        sch["layers"]["moe"] = moe_lib.moe_schema(cfg, Lc)
    else:
        sch["layers"]["mlp"] = mlp_schema(cfg, Lc)
    if not cfg.tie_embeddings:
        sch["lm_head"] = Spec((cfg.d_model, cfg.padded_vocab),
                              ("embed", "vocab"))
    return sch


def enc_dec_schema(cfg: ModelConfig):
    return {
        "embed": Spec((cfg.padded_vocab, cfg.d_model), ("vocab", "embed_tp"),
                      "embed"),
        "enc_layers": {
            "attn": attn_schema(cfg, cfg.enc_layers),
            "mlp": mlp_schema(cfg, cfg.enc_layers),
        },
        "enc_norm": Spec((cfg.d_model,), (None,), "ones"),
        "dec_layers": {
            "self_attn": attn_schema(cfg, cfg.dec_layers),
            "cross_attn": attn_schema(cfg, cfg.dec_layers),
            "mlp": mlp_schema(cfg, cfg.dec_layers),
        },
        "final_norm": Spec((cfg.d_model,), (None,), "ones"),
        "lm_head": Spec((cfg.d_model, cfg.padded_vocab), ("embed", "vocab")),
    }


def hybrid_schema(cfg: ModelConfig):
    """zamba2: periods of (attn_every mamba layers + 1 shared attn block)."""
    assert cfg.num_layers % cfg.attn_every == 0
    periods = cfg.num_layers // cfg.attn_every
    m = ssm_lib.mamba2_schema(cfg, stacked=(periods, cfg.attn_every),
                              prefix=("periods", "stack"))
    shared = {"attn": attn_schema(cfg, None), "mlp": mlp_schema(cfg, None)}
    return {
        "embed": Spec((cfg.padded_vocab, cfg.d_model), ("vocab", "embed_tp"),
                      "embed"),
        "final_norm": Spec((cfg.d_model,), (None,), "ones"),
        "mamba": m,
        "shared": shared,
    }


def ssm_lm_schema(cfg: ModelConfig):
    return {
        "embed": Spec((cfg.padded_vocab, cfg.d_model), ("vocab", "embed_tp"),
                      "embed"),
        "final_norm": Spec((cfg.d_model,), (None,), "ones"),
        "layers": ssm_lib.mamba2_schema(cfg, stacked=(cfg.num_layers,),
                                        prefix=("layers",)),
    }


def model_schema(cfg: ModelConfig):
    if cfg.family in ("dense", "vlm", "moe"):
        return decoder_lm_schema(cfg)
    if cfg.family == "enc_dec":
        return enc_dec_schema(cfg)
    if cfg.family == "hybrid":
        return hybrid_schema(cfg)
    if cfg.family == "ssm":
        return ssm_lm_schema(cfg)
    raise ValueError(cfg.family)


# ============================================================== embedding / logits
def embed_tokens(params, tokens, cfg: ModelConfig):
    dt = jnp.dtype(cfg.compute_dtype)
    out = params["embed"].astype(dt)[tokens]
    return constrain(out, "batch", None, "embed")


def lm_logits(params, h, cfg: ModelConfig):
    dt = jnp.dtype(cfg.compute_dtype)
    h = L.rms_norm(h, params["final_norm"], cfg.norm_eps).astype(dt)
    w = params.get("lm_head")
    if w is None:
        w = params["embed"].T
    logits = jnp.einsum("bsd,dv->bsv", h, w.astype(dt))
    logits = constrain(logits, "batch", None, "vocab").astype(jnp.float32)
    if cfg.padded_vocab != cfg.vocab_size:  # mask padded slots
        pad_mask = jnp.arange(cfg.padded_vocab) >= cfg.vocab_size
        logits = jnp.where(pad_mask[None, None], -1e30, logits)
    return logits


# ============================================================== decoder stacks
def _maybe_remat(fn, cfg: ModelConfig):
    return jax.checkpoint(fn) if cfg.remat == "full" else fn


def _scan_layers(body, h, stacked_params, cfg: ModelConfig, unroll: bool,
                 length: int):
    body = _maybe_remat(body, cfg)
    if unroll:
        for i in range(length):
            h, _ = body(h, jax.tree.map(lambda x: x[i], stacked_params))
        return h
    h, _ = jax.lax.scan(body, h, stacked_params)
    return h


def decoder_forward(params, tokens, cfg: ModelConfig, *,
                    patch_embeds=None, unroll=False):
    """Returns final hidden states (B, S, d)."""
    h = embed_tokens(params, tokens, cfg)
    if cfg.frontend == "vision":
        assert patch_embeds is not None
        pe = constrain(patch_embeds.astype(h.dtype), "batch", None, "embed")
        h = jnp.concatenate([pe, h], axis=1)

    aux_total = jnp.zeros((), jnp.float32)
    if cfg.family in ("dense", "vlm", "moe"):
        def body(carry, lp):
            x, aux_acc = carry
            x, _ = L.attention_block(lp["attn"], x, cfg, causal=True)
            if cfg.family == "moe":
                x, aux = moe_lib.moe_block(lp["moe"], x, cfg)
                aux_acc = aux_acc + aux
            else:
                x = L.swiglu_block(lp["mlp"], x, cfg)
            return (x, aux_acc), ()
        body = _maybe_remat(body, cfg)
        if unroll:
            carry = (h, aux_total)
            for i in range(cfg.num_layers):
                carry, _ = body(carry,
                                jax.tree.map(lambda x: x[i], params["layers"]))
            h, aux_total = carry
        else:
            (h, aux_total), _ = jax.lax.scan(body, (h, aux_total),
                                             params["layers"])
    elif cfg.family == "ssm":
        def body(carry, lp):
            x = carry
            x, _ = ssm_lib.mamba2_block(lp, x, cfg)
            return x, ()
        h = _scan_layers(body, h, params["layers"], cfg, unroll,
                         cfg.num_layers)
        aux_total = jnp.zeros((), jnp.float32)
    elif cfg.family == "hybrid":
        periods = cfg.num_layers // cfg.attn_every
        shared = params["shared"]

        def period_body(carry, pp):
            x = carry
            def inner(c, lp):
                c, _ = ssm_lib.mamba2_block(lp, c, cfg)
                return c, ()
            x, _ = jax.lax.scan(inner, x, pp)
            x, _ = L.attention_block(shared["attn"], x, cfg, causal=True)
            x = L.swiglu_block(shared["mlp"], x, cfg)
            return x, ()
        pb = _maybe_remat(period_body, cfg)
        if unroll:
            for i in range(periods):
                h, _ = pb(h, jax.tree.map(lambda x: x[i], params["mamba"]))
        else:
            h, _ = jax.lax.scan(pb, h, params["mamba"])
    else:
        raise ValueError(cfg.family)
    return h, aux_total


def encoder_forward(params, frames, cfg: ModelConfig, unroll=False):
    h = constrain(frames.astype(jnp.dtype(cfg.compute_dtype)),
                  "batch", None, "embed")

    def body(carry, lp):
        x = carry
        x, _ = L.attention_block(lp["attn"], x, cfg, causal=False)
        x = L.swiglu_block(lp["mlp"], x, cfg)
        return x, ()
    h = _scan_layers(body, h, params["enc_layers"], cfg, unroll,
                     cfg.enc_layers)
    return L.rms_norm(h, params["enc_norm"], cfg.norm_eps)


def enc_dec_forward(params, frames, tokens, cfg: ModelConfig, unroll=False):
    enc_out = encoder_forward(params, frames, cfg, unroll=unroll)
    dt = jnp.dtype(cfg.compute_dtype)
    h = embed_tokens(params, tokens, cfg)

    def body(carry, lp):
        x = carry
        x, _ = L.attention_block(lp["self_attn"], x, cfg, causal=True)
        # cross attention: k/v from encoder output
        ca = lp["cross_attn"]
        hn = L.rms_norm(x, ca["norm"], cfg.norm_eps).astype(dt)
        q = jnp.einsum("bsd,dhk->bshk", hn, ca["wq"].astype(dt))
        k = jnp.einsum("bsd,dhk->bshk", enc_out.astype(dt),
                       ca["wk"].astype(dt))
        v = jnp.einsum("bsd,dhk->bshk", enc_out.astype(dt),
                       ca["wv"].astype(dt))
        att = L.full_attention(q, k, v, causal=False)
        x = x + jnp.einsum("bshk,hkd->bsd", att, ca["wo"].astype(dt))
        x = L.swiglu_block(lp["mlp"], x, cfg)
        return x, ()
    h = _scan_layers(body, h, params["dec_layers"], cfg, unroll,
                     cfg.dec_layers)
    return h
