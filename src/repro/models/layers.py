"""Shared neural building blocks: norms, rotary, attention, MLPs.

All functions are pure; params are pytrees produced from models/schema.py.
Activation sharding is annotated through ``repro.launch.sharding.constrain``
with *logical* axis names so the same model code runs unsharded on CPU and
sharded on a production mesh.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.launch.sharding import constrain


# ----------------------------------------------------------------------------- norms
def rms_norm(x, weight, eps: float):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps)
    return (out * weight.astype(jnp.float32)).astype(dt)


# ----------------------------------------------------------------------------- rotary
def rotary_embedding(positions, head_dim: int, theta: float):
    """positions: (..., S) int -> (cos, sin) of shape (..., S, head_dim//2)."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    angles = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(angles), jnp.sin(angles)


def apply_rotary(x, cos, sin):
    """x: (B, S, H, D); cos/sin: (B, S, D//2) or (S, D//2)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    if cos.ndim == 2:
        cos, sin = cos[None], sin[None]
    cos, sin = cos[:, :, None, :], sin[:, :, None, :]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ----------------------------------------------------------------------------- attention cores
def _gqa_split(q, num_kv: int):
    """(B,S,H,D) -> (B,S,KV,G,D) with G = H // KV query groups."""
    b, s, h, d = q.shape
    return q.reshape(b, s, num_kv, h // num_kv, d)


def full_attention(q, k, v, *, causal: bool, q_offset=0, kv_len=None):
    """Reference full attention with GQA. q:(B,Sq,H,D), k/v:(B,Sk,KV,D).

    ``q_offset`` is the absolute position of q[0] (for decode).
    ``kv_len`` optionally masks out cache positions >= kv_len.
    """
    b, sq, h, d = q.shape
    kv = k.shape[2]
    qg = _gqa_split(q, kv)                      # (B,Sq,KV,G,D)
    scale = d ** -0.5
    # bf16 operands + f32 accumulation (MXU-native): avoids materializing
    # f32 copies of the KV cache (2x cache traffic on decode)
    logits = jnp.einsum("bqkgd,bskd->bkgqs", qg, k,
                        preferred_element_type=jnp.float32) * scale
    sk = k.shape[1]
    if causal:
        qpos = jnp.arange(sq) + q_offset
        kpos = jnp.arange(sk)
        mask = kpos[None, :] <= qpos[:, None]   # (Sq, Sk)
        logits = jnp.where(mask[None, None, None], logits, -1e30)
    if kv_len is not None:
        valid = jnp.arange(sk)[None, :] < kv_len[:, None]   # (B, Sk)
        logits = jnp.where(valid[:, None, None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, sq, h, d).astype(q.dtype)


def blockwise_attention(q, k, v, *, causal: bool, block_q: int, block_kv: int):
    """Memory-O(S*block) causal attention (online softmax), pure jnp.

    This is the production path for long prefill; it is also the oracle the
    Pallas flash kernel is validated against (kernels/flash_attention/ref.py
    re-exports it).  Causal block skipping: the kv loop for q block i only
    runs over blocks overlapping [0, (i+1)*block_q) — a dynamic fori_loop
    bound, so no 2x masked-compute waste.
    """
    b, sq, h, d = q.shape
    kv_heads = k.shape[2]
    g = h // kv_heads
    sk = k.shape[1]
    block_q = min(block_q, sq)
    block_kv = min(block_kv, sk)
    assert sq % block_q == 0 and sk % block_kv == 0
    nq, nk = sq // block_q, sk // block_kv
    scale = d ** -0.5

    qr = q.reshape(b, nq, block_q, kv_heads, g, d)
    kr = k.reshape(b, nk, block_kv, kv_heads, d)
    vr = v.reshape(b, nk, block_kv, kv_heads, d)

    def q_block(iq):
        qi = jax.lax.dynamic_index_in_dim(qr, iq, 1, keepdims=False)
        qpos = iq * block_q + jnp.arange(block_q)

        def kv_step(ik, carry):
            acc, m, l = carry
            ki = jax.lax.dynamic_index_in_dim(kr, ik, 1, keepdims=False)
            vi = jax.lax.dynamic_index_in_dim(vr, ik, 1, keepdims=False)
            s = jnp.einsum("bqkgd,bskd->bkgqs", qi, ki,
                           preferred_element_type=jnp.float32) * scale
            if causal:
                kpos = ik * block_kv + jnp.arange(block_kv)
                mask = kpos[None, :] <= qpos[:, None]
                s = jnp.where(mask[None, None, None], s, -1e30)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + p.sum(axis=-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bkgqs,bskd->bkgqd", p.astype(vi.dtype), vi,
                preferred_element_type=jnp.float32)
            return (acc_new, m_new, l_new)

        acc0 = jnp.zeros((b, kv_heads, g, block_q, d), jnp.float32)
        m0 = jnp.full((b, kv_heads, g, block_q), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((b, kv_heads, g, block_q), jnp.float32)
        if causal:
            n_valid = ((iq + 1) * block_q + block_kv - 1) // block_kv
        else:
            n_valid = nk
        acc, m, l = jax.lax.fori_loop(0, n_valid, kv_step, (acc0, m0, l0))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return out  # (B,KV,G,bq,D)

    outs = jax.lax.map(q_block, jnp.arange(nq))          # (nq,B,KV,G,bq,D)
    out = jnp.moveaxis(outs, 0, 3)                       # (B,KV,G,nq,bq,D)
    out = out.reshape(b, kv_heads, g, sq, d)
    out = jnp.moveaxis(out, 3, 1).reshape(b, sq, h, d)
    return out.astype(q.dtype)


# ----------------------------------------------------------------------------- attention layer
def attention_block(p, x, cfg, *, causal=True, positions=None,
                    kv_cache=None, cache_len=None, cross_kv=None):
    """Pre-norm attention block with rotary + GQA.

    Modes:
      * training/prefill: kv_cache is None -> attends within x.
      * decode:           kv_cache=(k,v) of shape (B,S,KV,D); x is the new
                          token(s); returns (out, new_kv_entries).
      * cross-attention:  cross_kv=(k,v) precomputed from the encoder.
    """
    from repro.configs.base import ModelConfig  # local to avoid cycles
    dt = jnp.dtype(cfg.compute_dtype)
    b, s, _ = x.shape
    h = rms_norm(x, p["norm"], cfg.norm_eps).astype(dt)

    q = jnp.einsum("bsd,dhk->bshk", h, p["wq"].astype(dt))
    q = constrain(q, "batch", None, "heads", None)
    if cross_kv is None:
        k = jnp.einsum("bsd,dhk->bshk", h, p["wk"].astype(dt))
        v = jnp.einsum("bsd,dhk->bshk", h, p["wv"].astype(dt))
        if positions is None:
            positions = jnp.arange(s)
        cos, sin = rotary_embedding(positions, cfg.head_dim, cfg.rope_theta)
        q = apply_rotary(q, cos, sin)
        k = apply_rotary(k, cos, sin)
    new_kv = None
    if cross_kv is not None:
        k, v = cross_kv
        out = full_attention(q, k, v, causal=False)
    elif kv_cache is not None:
        ck, cv = kv_cache  # (B, S_max, KV, D) seq-sharded on the model axis
        ck = jax.lax.dynamic_update_slice_in_dim(ck, k.astype(ck.dtype), 0, 1)
        cv = jax.lax.dynamic_update_slice_in_dim(cv, v.astype(cv.dtype), 0, 1)
        # NOTE: dynamic_update at position cache_len is handled by the caller
        # (serving engine) via roll-free indexed update; here we receive the
        # already-positioned update through `positions`.
        raise RuntimeError("use decode_attention for cached decode")
    else:
        impl = cfg.attn_impl
        if impl == "auto":
            impl = "blockwise" if s > 8192 else "full"
        if impl == "blockwise":
            out = blockwise_attention(q, k, v, causal=causal,
                                      block_q=cfg.flash_block_q,
                                      block_kv=cfg.flash_block_kv)
        else:
            out = full_attention(q, k, v, causal=causal)
        new_kv = (k, v)
    out = constrain(out, "batch", None, "heads", None)
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(dt))
    return x + constrain(out, "batch", None, "embed"), new_kv


def decode_attention(p, x, cfg, *, cache_k, cache_v, cache_len,
                     cross_kv=None, active=None):
    """One-token decode against a KV cache.

    cache_k/v: (B, S_max, KV, D); cache_len: (B,) current lengths.
    Returns (out, (cache_k, cache_v)) with the new token written at cache_len.
    """
    dt = jnp.dtype(cfg.compute_dtype)
    b, s, _ = x.shape  # s == 1
    h = rms_norm(x, p["norm"], cfg.norm_eps).astype(dt)
    q = jnp.einsum("bsd,dhk->bshk", h, p["wq"].astype(dt))
    if cross_kv is not None:
        out = full_attention(q, *cross_kv, causal=False)
        out = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(dt))
        return x + out, (cache_k, cache_v)

    k = jnp.einsum("bsd,dhk->bshk", h, p["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", h, p["wv"].astype(dt))
    cos, sin = rotary_embedding(cache_len[:, None], cfg.head_dim,
                                cfg.rope_theta)
    q = apply_rotary(q, cos, sin)
    k = apply_rotary(k, cos, sin)
    # scatter the new kv at position cache_len (per batch row); inactive
    # slots keep their old cache contents (continuous-batching mask)
    bidx = jnp.arange(b)
    k_new, v_new = k[:, 0].astype(cache_k.dtype), v[:, 0].astype(cache_v.dtype)
    if active is not None:
        k_new = jnp.where(active[:, None, None], k_new,
                          cache_k[bidx, cache_len])
        v_new = jnp.where(active[:, None, None], v_new,
                          cache_v[bidx, cache_len])
    cache_k = cache_k.at[bidx, cache_len].set(k_new)
    cache_v = cache_v.at[bidx, cache_len].set(v_new)
    out = full_attention(q, cache_k.astype(dt), cache_v.astype(dt),
                         causal=False, kv_len=cache_len + 1)
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(dt))
    return x + out, (cache_k, cache_v)


def paged_decode_attention(p, x, cfg, *, pool_k, pool_v, block_tables,
                           cache_len, active=None, impl="auto"):
    """One-token decode against a *paged* KV cache (block pool + tables).

    pool_k/v: (num_blocks, bs, KV, D) — one shared device pool; each
    lane's logical positions map through block_tables (B, max_blocks) to
    physical pool rows.  Writes the new kv at logical position
    ``cache_len`` (physical: block ``bt[b, cache_len // bs]``, offset
    ``cache_len % bs``); inactive lanes are routed to an out-of-range
    index and dropped (``mode="drop"``), the paged analogue of the dense
    path's keep-old-value masking.  The attention core
    (``kernels.paged_attention``) is bit-identical to
    ``decode_attention``'s ``full_attention`` on CPU backends and a
    Pallas kernel on TPU.

    Returns (out, (pool_k, pool_v)).
    """
    from repro.kernels.paged_attention import paged_attention
    dt = jnp.dtype(cfg.compute_dtype)
    b, s, _ = x.shape  # s == 1
    h = rms_norm(x, p["norm"], cfg.norm_eps).astype(dt)
    q = jnp.einsum("bsd,dhk->bshk", h, p["wq"].astype(dt))
    k = jnp.einsum("bsd,dhk->bshk", h, p["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", h, p["wv"].astype(dt))
    cos, sin = rotary_embedding(cache_len[:, None], cfg.head_dim,
                                cfg.rope_theta)
    q = apply_rotary(q, cos, sin)
    k = apply_rotary(k, cos, sin)
    nb, bs = pool_k.shape[0], pool_k.shape[1]
    bidx = jnp.arange(b)
    blk = block_tables[bidx, cache_len // bs]
    off = cache_len % bs
    if active is not None:
        blk = jnp.where(active, blk, nb)   # OOB -> write dropped
    k_new = k[:, 0].astype(pool_k.dtype)
    v_new = v[:, 0].astype(pool_v.dtype)
    pool_k = pool_k.at[blk, off].set(k_new, mode="drop")
    pool_v = pool_v.at[blk, off].set(v_new, mode="drop")
    out = paged_attention(q[:, 0], pool_k.astype(dt), pool_v.astype(dt),
                          block_tables, cache_len + 1, impl=impl)[:, None]
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(dt))
    return x + out, (pool_k, pool_v)


def paged_chunk_attention(p, x, cfg, *, pool_k, pool_v, bt_row, off,
                          history=True):
    """Chunk prefill over one slot's paged KV blocks.

    x: (1, C, d) — C prompt tokens at absolute positions off..off+C-1.
    bt_row: (max_blocks,) the slot's block table.  Gathers the slot's
    blocks into a contiguous (1, S_max, KV, D) view, writes the chunk's
    kv at ``off`` (a block-table append in logical terms), attends
    causally at ``q_offset=off`` over history + chunk, and scatters the
    rows back through the table (sentinel entries dropped).  Per-position
    math matches the streamed decode path bit-for-bit for causal
    families — masked history/pad positions contribute exact zeros —
    which is what lets multi-chunk prefill subsume prefill-with-history.

    ``history=False`` is the first-chunk (``off == 0``) specialization:
    with no history every gathered position is masked, so the gather /
    update-slice / full-view attention collapses to causal attention
    within the chunk plus a scatter of only the chunk's own blocks.
    Identical per-position math (masked columns contribute exact zeros
    either way), a fraction of the memory traffic — this is what keeps
    paged admission prefill on par with the dense path's.

    Returns (out, pool_k, pool_v).
    """
    dt = jnp.dtype(cfg.compute_dtype)
    b, c, _ = x.shape  # b == 1
    h = rms_norm(x, p["norm"], cfg.norm_eps).astype(dt)
    q = jnp.einsum("bsd,dhk->bshk", h, p["wq"].astype(dt))
    k = jnp.einsum("bsd,dhk->bshk", h, p["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", h, p["wv"].astype(dt))
    positions = off + jnp.arange(c)
    cos, sin = rotary_embedding(positions, cfg.head_dim, cfg.rope_theta)
    q = apply_rotary(q, cos, sin)
    k = apply_rotary(k, cos, sin)
    nb, bs = pool_k.shape[0], pool_k.shape[1]
    if not history:
        out = full_attention(q, k.astype(dt), v.astype(dt), causal=True)
        out = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(dt))
        n_blk = -(-c // bs)
        pad = ((0, n_blk * bs - c), (0, 0), (0, 0))
        rows_k = jnp.pad(k[0].astype(pool_k.dtype), pad)
        rows_v = jnp.pad(v[0].astype(pool_v.dtype), pad)
        pool_k = pool_k.at[bt_row[:n_blk]].set(
            rows_k.reshape(n_blk, bs, *pool_k.shape[2:]), mode="drop")
        pool_v = pool_v.at[bt_row[:n_blk]].set(
            rows_v.reshape(n_blk, bs, *pool_v.shape[2:]), mode="drop")
        return x + out, pool_k, pool_v
    mb = bt_row.shape[0]
    bt = jnp.clip(bt_row, 0, nb - 1)
    rows_k = pool_k[bt].reshape(1, mb * bs, *pool_k.shape[2:])
    rows_v = pool_v[bt].reshape(1, mb * bs, *pool_v.shape[2:])
    rows_k = jax.lax.dynamic_update_slice(
        rows_k, k.astype(rows_k.dtype), (0, off, 0, 0))
    rows_v = jax.lax.dynamic_update_slice(
        rows_v, v.astype(rows_v.dtype), (0, off, 0, 0))
    out = full_attention(q, rows_k.astype(dt), rows_v.astype(dt),
                         causal=True, q_offset=off)
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(dt))
    pool_k = pool_k.at[bt_row].set(
        rows_k.reshape(mb, bs, *pool_k.shape[2:]), mode="drop")
    pool_v = pool_v.at[bt_row].set(
        rows_v.reshape(mb, bs, *pool_v.shape[2:]), mode="drop")
    return x + out, pool_k, pool_v


# ----------------------------------------------------------------------------- MLP
def swiglu_block(p, x, cfg):
    dt = jnp.dtype(cfg.compute_dtype)
    h = rms_norm(x, p["norm"], cfg.norm_eps).astype(dt)
    g = jnp.einsum("bsd,df->bsf", h, p["w_gate"].astype(dt))
    u = jnp.einsum("bsd,df->bsf", h, p["w_up"].astype(dt))
    act = constrain(jax.nn.silu(g) * u, "batch", None, "ff")
    out = jnp.einsum("bsf,fd->bsd", act, p["w_down"].astype(dt))
    return x + constrain(out, "batch", None, "embed")
